"""Runtime lock-order recorder for the hierarchy in ``repro.core.locking``.

When installed (``pytest --sanitize``), :class:`LockTracer` becomes the
factory behind ``locking.make_lock``/``make_rlock``/``make_condition``:
every registered lock is wrapped in a :class:`TracedLock` that maintains
a per-thread stack of held locks, checks the hierarchy on every
*blocking* acquisition, and accumulates a global class-level acquisition
graph for deadlock (cycle) detection.

Error codes (collected in :attr:`LockTracer.violations`):

* ``LC001`` — blocking acquisition of an ordered lock whose level is not
  strictly above the highest ordered level already held (a hierarchy
  inversion: two threads doing this in opposite orders deadlock).
* ``LC002`` — same-class stacking of a ``multi`` class with a
  non-increasing order key (page locks must be taken in ascending page
  order).
* ``LC003`` — a cycle in the class-level acquisition graph, reported by
  :meth:`LockTracer.check_cycles` at detach (a potential deadlock even
  if no run ever interleaved into it).
* ``LC004`` — backend I/O (``pwrite``/``pwritev``/``fsync``) issued while
  holding a shard alloc lock: the device round-trip would serialize every
  writer behind it.

Non-blocking (try-lock) acquisitions are exempt from LC001/LC002 and do
not feed the cycle graph — they cannot deadlock — but a successful one
still counts as held for LC004.

Violations are :class:`repro.analysis.trace.Violation` records,
deduplicated by (code, lock classes, site) so a sweep reports each
distinct pattern once.

When a :class:`repro.analysis.racecheck.RaceCheck` is attached
(``tracer.race``), every acquire/release of a TracedLock is forwarded to
it — the happens-before edges of the vector-clock analysis — and the
tracer's per-thread held stack doubles as the lockset.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.trace import Reporter, tid, tname
from repro.core.locking import LEAF_LEVEL


class TracedLock:
    """Hierarchy-aware wrapper around ``threading.Lock``/``RLock``."""

    def __init__(self, tracer: "LockTracer", name: str, level: int,
                 multi: bool, order_key=None, group=None, rlock: bool = False):
        self._tracer = tracer
        self.name = name
        self.level = level
        self.multi = multi
        self.order_key = order_key
        self.group = group
        self._rlock = rlock
        self._inner = threading.RLock() if rlock else threading.Lock()
        self._owner: Optional[int] = None
        self._count = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        me = tid()
        if self._rlock and self._owner == me:
            self._inner.acquire(blocking, timeout)
            self._count += 1
            return True
        if blocking:
            self._tracer.before_blocking_acquire(self)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._owner = me
            self._count = 1
            self._tracer.note_acquired(self)
        return ok

    def release(self) -> None:
        if self._rlock and self._count > 1:
            self._count -= 1
            self._inner.release()
            return
        self._owner = None
        self._count = 0
        self._tracer.note_released(self)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()

    def locked(self) -> bool:
        if self._rlock:
            return self._owner is not None
        return self._inner.locked()

    # ``threading.Condition`` protocol.  Without ``_is_owned`` the stdlib
    # falls back to probing ``acquire(False)`` — which *succeeds* reentrantly
    # on an RLock-backed wrapper, so notify() would wrongly conclude the
    # lock is un-owned and raise.
    def _is_owned(self) -> bool:
        return self._owner == tid()

    def _release_save(self):
        count = self._count if self._rlock else 1
        self._owner = None
        self._count = 0
        self._tracer.note_released(self)
        for _ in range(count):
            self._inner.release()
        return count

    def _acquire_restore(self, count) -> None:
        for _ in range(count):
            self._inner.acquire()
        self._owner = tid()
        self._count = count
        self._tracer.note_acquired(self)

    def __repr__(self) -> str:
        key = f", key={self.order_key}" if self.order_key is not None else ""
        return f"<TracedLock {self.name}@{self.level}{key}>"


class LockTracer:
    """Global recorder shared by every TracedLock of a sanitized run."""

    def __init__(self):
        self._tls = threading.local()
        self._mu = threading.Lock()
        self._rep = Reporter()
        self.violations = self._rep.violations
        self.edges: Dict[Tuple[str, str], str] = {}
        self.stats_acquisitions = 0
        self.race = None                  # optional RaceCheck (HB edges)

    # factory used by repro.core.locking
    def traced_lock(self, name: str, info: dict, order_key=None, group=None,
                    rlock: bool = False) -> TracedLock:
        return TracedLock(self, name, info["level"], info["multi"],
                          order_key=order_key, group=group, rlock=rlock)

    # ------------------------------------------------------------ held state
    def _held(self) -> List[TracedLock]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def held_locks(self) -> List[TracedLock]:
        """The calling thread's current lockset (racecheck reads this)."""
        return self._held()

    def _flag(self, code: str, key: Tuple, msg: str) -> None:
        self._rep.flag(code, msg, key=(code,) + key)

    # --------------------------------------------------------------- checks
    def before_blocking_acquire(self, lock: TracedLock) -> None:
        held = self._held()
        if not held:
            return
        me = tname()
        with self._mu:
            for h in held:
                if h.name != lock.name or not lock.multi:
                    self.edges.setdefault((h.name, lock.name), me)
        if lock.level >= LEAF_LEVEL:
            return                        # leaves: edges only, no level rule
        ordered = [h for h in held if h.level < LEAF_LEVEL]
        if not ordered:
            return
        top = max(ordered, key=lambda h: h.level)
        if lock.level > top.level:
            return
        if lock.level == top.level and lock.multi and lock.name == top.name:
            same = [h for h in ordered
                    if h.name == lock.name and h.group == lock.group]
            if same and lock.order_key is not None:
                prev = same[-1].order_key
                if prev is not None and not (lock.order_key > prev):
                    self._flag("LC002", (lock.name, me),
                               f"[{me}] {lock.name} stacked with "
                               f"non-increasing order key {lock.order_key!r} "
                               f"after {prev!r}")
            return
        self._flag("LC001", (lock.name, top.name, me),
                   f"[{me}] blocking acquire of {lock!r} while holding "
                   f"{top!r} (levels must strictly increase; held: "
                   f"{[h.name for h in held]})")

    def note_acquired(self, lock: TracedLock) -> None:
        self._held().append(lock)
        self.stats_acquisitions += 1
        if self.race is not None:
            self.race.on_acquire(lock)

    def note_released(self, lock: TracedLock) -> None:
        if self.race is not None:
            self.race.on_release(lock)
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is lock:
                del held[i]
                return

    # ---------------------------------------------------------- backend I/O
    def on_backend_io(self, kind: str, detail: str = "") -> None:
        held = [h.name for h in self._held()]
        if "shard" in held:
            me = tname()
            self._flag("LC004", (kind, me),
                       f"[{me}] backend {kind} {detail} issued while "
                       f"holding a shard alloc lock (held: {held})")

    # --------------------------------------------------------------- cycles
    def check_cycles(self) -> List[str]:
        """DFS the class-level acquisition graph; a cycle is a potential
        deadlock even if no run ever interleaved into it."""
        adj: Dict[str, Set[str]] = {}
        for (a, b) in self.edges:
            if a != b:
                adj.setdefault(a, set()).add(b)
        found: List[str] = []
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in adj}

        def dfs(n: str, path: List[str]) -> None:
            color[n] = GRAY
            path.append(n)
            for m in adj.get(n, ()):
                if color.get(m, WHITE) == GRAY:
                    cyc = path[path.index(m):] + [m]
                    found.append(" -> ".join(cyc))
                elif color.get(m, WHITE) == WHITE:
                    color.setdefault(m, WHITE)
                    dfs(m, path)
            path.pop()
            color[n] = BLACK

        for n in list(adj):
            if color.get(n, WHITE) == WHITE:
                dfs(n, [])
        for cyc in found:
            self._flag("LC003", (cyc,), f"acquisition-order cycle: {cyc}")
        return found

    def summary(self) -> dict:
        return {
            "violations": [str(v) for v in self.violations],
            "acquisitions": self.stats_acquisitions,
            "edges": sorted(f"{a}->{b}" for a, b in self.edges),
        }
