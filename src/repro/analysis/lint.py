"""AST static pass over ``repro.core`` + ``repro.obs`` —
``python -m repro.analysis.lint``.

Checks (source of truth for the hierarchy is the LOCK HIERARCHY table in
``repro/core/locking.py``'s docstring, parsed at startup):

* ``L001`` — every ``threading.Lock``/``RLock``/``Condition`` construction
  in ``repro.core`` must go through the ``locking.make_*`` factories
  (direct constructions are invisible to the runtime checker), and every
  factory call must name a class present in the hierarchy table.
* ``L002`` — no ``time.sleep`` and no backend I/O call (``pwrite``,
  ``pwritev``, ``pread``, ``preadv``, ``fsync``) syntactically inside a
  ``with <shard lock>`` block: the shard alloc lock serializes every
  writer of that shard, so a device round-trip under it is a throughput
  cliff.  Shard-lock attributes are discovered from
  ``make_lock("shard")`` / ``make_condition("shard", ...)`` assignments.
* ``L003`` — every ``<obj>.psync()`` call must be dominated by a
  ``<obj>.pwb(...)`` (or ``store_flush``) on the same object earlier in
  the enclosing function: a psync with nothing flushed persists nothing,
  which almost always means the pwb is missing, not the psync redundant.
  (Dominance is approximated by source order within the function —
  sufficient for the straight-line persist protocols this codebase uses.)
* ``L004`` — a field declared in a class's ``GUARDED_BY`` table (see the
  GUARDED-BY CONTRACT in ``core/locking.py``) accessed as ``self.<field>``
  outside a ``with self.<its guard>`` block.  ``__init__``/``__new__``,
  ``*_locked``-suffixed methods (the callers-hold-it convention), and
  nested function/lambda bodies are exempt; ``"write:lock"`` specs are
  checked on writes only; ``None``/``"volatile"`` specs are not checked.
  (Syntactic approximation: accesses through aliases or explicit
  acquire/release pairs need an allow comment.)
* ``L005`` — a lock-owning class (one that builds a lock via the
  ``make_*`` factories) rebinds a *public* ``self.<attr>`` outside
  ``__init__`` with no ``GUARDED_BY`` declaration for it: mutable shared
  state the race detector cannot see.  Annotation completeness — the
  guarded-by table's version of the hierarchy-table L001 rule.
* ``L006`` — every metric/span name literal (arguments to the
  ``repro.obs.metrics`` constructors / ``Registry`` binders, keys of a
  ``bind_group`` dict, keys of a ``_LEVELS`` span table) must match the
  documented ``subsystem.noun_unit`` grammar (see
  ``src/repro/obs/README.md``); the registry enforces the same rule at
  runtime, this catches names on paths tests never execute.

Suppress a finding by appending ``# lint: allow(CODE)`` to the flagged
line.  Exit status: 0 when clean, 1 with findings (one per line:
``path:line: CODE message``).
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Dict, List, Set, Tuple

from repro.core.locking import parse_hierarchy
from repro.obs.metrics import NAME_RE as _METRIC_NAME_RE

_FACTORIES = {"make_lock", "make_rlock", "make_condition"}
_PRIMITIVES = {"Lock", "RLock", "Condition"}
_IO_CALLS = {"pwrite", "pwritev", "pread", "preadv", "fsync"}
#: call names whose first string-literal argument is a metric/span name
_METRIC_CTORS = {"Counter", "Gauge", "Histogram", "BoundGauge",
                 "counter", "gauge", "histogram", "bind", "bind_summary",
                 "merged_snapshot"}


class Finding:
    def __init__(self, path: Path, line: int, code: str, msg: str):
        self.path, self.line, self.code, self.msg = path, line, code, msg

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.msg}"


def _factory_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _is_threading_primitive(call: ast.Call) -> bool:
    f = call.func
    return (isinstance(f, ast.Attribute) and f.attr in _PRIMITIVES
            and isinstance(f.value, ast.Name) and f.value.id == "threading")


def _literal_class_arg(call: ast.Call):
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


def collect_shard_attrs(trees: Dict[Path, ast.Module]) -> Set[str]:
    """Attribute names assigned from ``make_lock("shard")`` /
    ``make_condition("shard", ...)`` — the ``with`` targets L002 guards."""
    attrs: Set[str] = set()
    for tree in trees.values():
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assign) or \
                    not isinstance(node.value, ast.Call):
                continue
            call = node.value
            if _factory_name(call) not in _FACTORIES:
                continue
            if _literal_class_arg(call) != "shard":
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute):
                    attrs.add(tgt.attr)
    return attrs


def _suppressed(src_lines: List[str], line: int, code: str) -> bool:
    if 0 < line <= len(src_lines):
        return f"lint: allow({code})" in src_lines[line - 1]
    return False


# ------------------------------------------------------- guarded-by helpers

def _self_attr(node) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name) and node.value.id == "self")


def _eval_spec(v):
    """Best-effort static value of one GUARDED_BY entry."""
    if isinstance(v, ast.Constant):
        return v.value                    # str or None
    if isinstance(v, ast.Tuple):
        return tuple(e.value for e in v.elts
                     if isinstance(e, ast.Constant))
    if isinstance(v, ast.Attribute) and v.attr == "VOLATILE":
        return "volatile"
    if isinstance(v, ast.Name) and v.id == "VOLATILE":
        return "volatile"
    return None                           # unknown: treat as HB-only


def _guarded_table(cls_node: ast.ClassDef):
    """The class's ``GUARDED_BY`` dict, statically evaluated; None when
    the class declares none."""
    for stmt in cls_node.body:
        if not isinstance(stmt, ast.Assign):
            continue
        for tgt in stmt.targets:
            if isinstance(tgt, ast.Name) and tgt.id == "GUARDED_BY" \
                    and isinstance(stmt.value, ast.Dict):
                out = {}
                for k, v in zip(stmt.value.keys, stmt.value.values):
                    if isinstance(k, ast.Constant) and \
                            isinstance(k.value, str):
                        out[k.value] = _eval_spec(v)
                return out
    return None


def _owns_lock(cls_node: ast.ClassDef) -> bool:
    for node in ast.walk(cls_node):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _factory_name(node.value) in _FACTORIES \
                and any(_self_attr(t) for t in node.targets):
            return True
    return False


def _required_guards(spec, is_write: bool):
    """The set of ``self.<attr>`` guard names satisfying the spec for this
    access, or None when the access is unchecked."""
    if spec is None or spec == "volatile":
        return None
    if isinstance(spec, str):
        if spec.startswith("write:"):
            return {spec[len("write:"):]} if is_write else None
        return {spec}
    if isinstance(spec, tuple):
        return set(spec)
    return None


def lint_file(path: Path, tree: ast.Module, hierarchy: Dict[str, dict],
              shard_attrs: Set[str]) -> List[Finding]:
    findings: List[Finding] = []
    src_lines = path.read_text().splitlines()

    def flag(node: ast.AST, code: str, msg: str) -> None:
        if not _suppressed(src_lines, node.lineno, code):
            findings.append(Finding(path, node.lineno, code, msg))

    is_locking_mod = path.name == "locking.py"

    # ---- L001: constructions + factory names ----------------------------
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "threading":
            for alias in node.names:
                if alias.name in _PRIMITIVES:
                    flag(node, "L001",
                         f"import of threading.{alias.name}: construct "
                         f"locks via repro.core.locking.make_*")
        if not isinstance(node, ast.Call):
            continue
        if _is_threading_primitive(node) and not is_locking_mod:
            flag(node, "L001",
                 f"direct threading.{node.func.attr}() in core/ — use "
                 f"repro.core.locking.make_* so the hierarchy checker "
                 f"sees it")
        if _factory_name(node) in _FACTORIES and not is_locking_mod:
            name = _literal_class_arg(node)
            if name is None:
                flag(node, "L001",
                     "lock class name must be a string literal (the "
                     "hierarchy table is static)")
            elif name not in hierarchy:
                flag(node, "L001",
                     f"lock class {name!r} not in the hierarchy table "
                     f"(core/locking.py docstring)")

    # ---- L002: sleep / backend I/O under a shard lock -------------------
    if shard_attrs:
        for node in ast.walk(tree):
            if not isinstance(node, ast.With):
                continue
            if not any(isinstance(it.context_expr, ast.Attribute)
                       and it.context_expr.attr in shard_attrs
                       for it in node.items):
                continue
            for sub in ast.walk(node):
                if sub is node or not isinstance(sub, ast.Call):
                    continue
                fn = sub.func
                name = fn.attr if isinstance(fn, ast.Attribute) else (
                    fn.id if isinstance(fn, ast.Name) else "")
                if name == "sleep" or name in _IO_CALLS:
                    flag(sub, "L002",
                         f"{name}() syntactically inside a `with <shard "
                         f"lock>` block — every writer of the shard "
                         f"serializes behind it")

    # ---- L003: psync dominated by pwb on the same object ----------------
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        calls: List[Tuple[int, str, str]] = []   # (line, obj, method)
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Attribute) and \
                    sub.func.attr in ("psync", "pwb", "store_flush"):
                calls.append((sub.lineno, ast.unparse(sub.func.value),
                              sub.func.attr))
        for line, obj, meth in calls:
            if meth != "psync":
                continue
            if obj == "self" and fn.name in ("psync", "pfence"):
                continue                  # the primitive's own definition
            if not any(l < line and o == obj and m in ("pwb", "store_flush")
                       for l, o, m in calls):
                flag_node = ast.Expr(lineno=line)  # carries the lineno only
                flag(flag_node, "L003",
                     f"{obj}.psync() not dominated by a {obj}.pwb() in "
                     f"{fn.name}() — nothing was flush-requested here")

    # ---- L006: metric/span name grammar ---------------------------------
    def _check_metric_name(node: ast.AST, name: str) -> None:
        if not _METRIC_NAME_RE.match(name):
            flag(node, "L006",
                 f"metric/span name {name!r} violates the documented "
                 f"subsystem.noun_unit grammar (src/repro/obs/README.md)")

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fname = _factory_name(node)
            if fname in _METRIC_CTORS:
                lit = _literal_class_arg(node)
                if lit is not None:
                    _check_metric_name(node, lit)
            elif fname == "bind_group" and node.args and \
                    isinstance(node.args[0], ast.Dict):
                for k in node.args[0].keys:
                    if isinstance(k, ast.Constant) and \
                            isinstance(k.value, str):
                        _check_metric_name(k, k.value)
        elif isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Dict) and \
                any(isinstance(t, ast.Name) and t.id == "_LEVELS"
                    for t in node.targets):
            for k in node.value.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    _check_metric_name(k, k.value)

    # ---- L004/L005: the guarded-by contract -----------------------------
    for cls_node in ast.walk(tree):
        if not isinstance(cls_node, ast.ClassDef):
            continue
        table = _guarded_table(cls_node)
        if table:
            _check_l004(cls_node, table, flag)
        if _owns_lock(cls_node):
            _check_l005(cls_node, table or {}, flag)

    return findings


def _check_l004(cls_node: ast.ClassDef, table: dict, flag) -> None:
    """Guarded ``self.<field>`` accesses must sit inside a
    ``with self.<guard>`` block."""

    def with_guards(node: ast.With):
        names = set()
        for it in node.items:
            if _self_attr(it.context_expr):
                names.add(it.context_expr.attr)
        return names

    def visit(node, held):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return                        # nested defs run elsewhere
        if isinstance(node, ast.With):
            held = held | with_guards(node)
        elif _self_attr(node) and node.attr in table:
            is_write = isinstance(node.ctx, (ast.Store, ast.Del))
            req = _required_guards(table[node.attr], is_write)
            if req is not None and not (req & held):
                want = "|".join(sorted(req))
                flag(node, "L004",
                     f"{cls_node.name}.{node.attr} "
                     f"{'written' if is_write else 'read'} outside "
                     f"`with self.{want}` (its GUARDED_BY declaration)")
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for meth in cls_node.body:
        if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if meth.name in ("__init__", "__new__") or \
                meth.name.endswith("_locked"):
            continue
        for stmt in meth.body:
            visit(stmt, set())


def _check_l005(cls_node: ast.ClassDef, table: dict, flag) -> None:
    """Public attrs rebound outside __init__ need a GUARDED_BY entry."""
    seen: Set[str] = set()
    for meth in cls_node.body:
        if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if meth.name in ("__init__", "__new__"):
            continue
        for node in ast.walk(meth):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign,)):
                targets = [node.target]
            else:
                continue
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    _factory_name(node.value) in _FACTORIES:
                continue                  # the lock itself
            for tgt in targets:
                if not _self_attr(tgt):
                    continue
                attr = tgt.attr
                if attr.startswith("_") or attr in table or attr in seen:
                    continue
                seen.add(attr)
                flag(tgt, "L005",
                     f"public mutable attribute {cls_node.name}.{attr} "
                     f"assigned outside __init__ with no GUARDED_BY "
                     f"declaration — the race detector cannot check it")


def run(paths: List[Path]) -> List[Finding]:
    hierarchy = parse_hierarchy()
    files: List[Path] = []
    for p in paths:
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    trees = {f: ast.parse(f.read_text()) for f in files}
    shard_attrs = collect_shard_attrs(trees)
    findings: List[Finding] = []
    for f, tree in trees.items():
        findings.extend(lint_file(f, tree, hierarchy, shard_attrs))
    return findings


def main(argv: List[str]) -> int:
    import repro.core as core
    import repro.obs as obs
    defaults = [Path(core.__file__).parent, Path(obs.__file__).parent]
    paths = [Path(a) for a in argv] or defaults
    findings = run(paths)
    for f in findings:
        print(f)
    if findings:
        print(f"lint: {len(findings)} finding(s)")
        return 1
    nfiles = sum(len(list(p.rglob('*.py'))) if p.is_dir() else 1
                 for p in paths)
    print(f"lint: OK ({nfiles} files, hierarchy classes: "
          f"{len(parse_hierarchy())})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
