"""Correctness-analysis tooling for the NVMM engine.

Three cooperating checkers (see README.md in this directory):

* :mod:`repro.analysis.pmcheck`   — persistence-ordering sanitizer
  (pmemcheck-style shadow map over the NVMM commit protocols).
* :mod:`repro.analysis.lockcheck` — runtime lock-order recorder against
  the hierarchy in :mod:`repro.core.locking`.
* :mod:`repro.analysis.lint`      — AST static pass over ``repro.core``
  (``python -m repro.analysis.lint``).

:mod:`repro.analysis.sanitize` wires the two runtime checkers into a live
process (``pytest --sanitize`` uses it from ``tests/conftest.py``).
"""
