"""Eraser/FastTrack-style hybrid race detector for the engine's shared
state, driven by the ``GUARDED_BY`` contract in :mod:`repro.core.locking`.

The engine runs five kinds of threads against the same structures —
writer threads, per-shard drain threads, the pager writeback thread, the
rebalance thread, and recovery.  :mod:`lockcheck` proves the locks are
*ordered*; this module checks that shared fields are actually *covered*
by the lock that is supposed to guard them.

Epoch model
-----------
Each thread carries a vector clock (VC).  Happens-before edges advance
and join the clocks at every synchronization the engine uses:

* **lock release → acquire** of any ``TracedLock`` (the releaser's VC is
  joined into the lock, the acquirer joins the lock's VC; the releaser's
  own component then ticks).  Conditions share their lock, so
  ``notify``/``wait`` hand-offs — including the seq-commit hand-off
  through ``NVLog._seq_lock`` and the shard ``_space``/``_committed``
  conditions — are covered by the same edge.
* **Thread.start / Thread.join** — the parent's VC is snapshotted onto
  the child at ``start`` (consumed lazily at the child's first event);
  ``join`` merges the dead child's final VC into the joiner.  This is
  what makes single-threaded setup (``format``/``attach``) and
  post-shutdown stats reads race-free without any lock.
* **Event.set → Event.wait** — the generic hand-off channel
  (``drain_event``, ``stop_event``, the pager's ``pressure``).

Each access to a declared field records an *epoch* (thread, clock) plus
the thread's current lockset (the tracer's held stack).  Two accesses
race when **neither happens-before the other and their locksets are
disjoint** — the hybrid rule: a common lock means mutual exclusion, a
clock edge means ordering, and demanding both be absent keeps untracked
synchronization from producing false positives.

Error codes (one report per ``(code, class, field)``):

* ``RC001`` — write-write race: two writes with conflicting epochs and
  disjoint locksets.
* ``RC002`` — read-write race: a read and a write with conflicting
  epochs and disjoint locksets.
* ``RC003`` — a field declared ``GUARDED_BY`` was touched without its
  guard held *while unordered against another thread's accesses*.  The
  happens-before qualifier is what lets init/attach (single-threaded)
  and post-join teardown reads run clean while still catching every
  concurrent guard violation.

Spec handling (grammar in ``repro.core.locking``): ``"attr"`` guards
reads and writes; a tuple is any-of (condition aliases); ``"write:attr"``
checks writes only and removes reads from the analysis (immutable-swap
readers); ``None`` runs the epoch analysis with no RC003;
``locking.VOLATILE`` excludes the field entirely.

Instrumentation
---------------
:func:`instrument` patches a class's ``__getattribute__`` /
``__setattr__`` (works with ``__slots__``) to route declared-field
accesses to the active detector, and wraps ``__init__`` so
under-construction objects are exempt.  Container mutation
(``self.dirty[idx] = t``, ``list.append``) surfaces as an instrumented
*read* of the field — RC003 still checks the guard; the epoch analysis
sees it as a read.  All hooks dispatch through the module-global
:data:`_active` detector, so :func:`arm` can swap a local
:class:`RaceCheck` in for a planted-bug test without touching the
``--sanitize`` session state (the same trick as ``pmcheck.attach``).

Known blind spot: thread idents can be reused after ``join``; a shadow
epoch left by a dead thread is attributed to its successor (a possible
false *negative*, never a false positive).  Per-test shadow resets keep
the window small.
"""
from __future__ import annotations

import contextlib
import threading
import weakref
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.trace import Reporter, tid, tname
from repro.core import locking

__all__ = ["RaceCheck", "FieldSpec", "arm", "instrument", "install_core",
           "uninstall_core", "install_thread_hooks",
           "uninstall_thread_hooks", "set_active", "active"]

# the detector every instrumented hook routes through (swapped by arm())
_active: Optional["RaceCheck"] = None

# per-thread re-entrancy guard.  Detector code itself synchronizes (and
# ``current_thread()`` can mint a ``_DummyThread`` whose __init__ calls
# ``Event.set``): without this, a hook fired from inside a hook deadlocks
# on ``RaceCheck._mu``.  Inner hook calls become no-ops instead.
_busy = threading.local()


def _enter_hook() -> bool:
    if getattr(_busy, "on", False):
        return False
    _busy.on = True
    return True


def _exit_hook() -> None:
    _busy.on = False


def set_active(rc: Optional["RaceCheck"]) -> None:
    global _active
    _active = rc


def active() -> Optional["RaceCheck"]:
    return _active


# --------------------------------------------------------------------- specs

class FieldSpec:
    """Parsed ``GUARDED_BY`` entry."""

    __slots__ = ("mode", "guards", "display")

    def __init__(self, mode: str, guards: Tuple[str, ...], display: str):
        self.mode = mode          # 'guard' | 'write' | 'hb'
        self.guards = guards
        self.display = display


def parse_spec(raw) -> Optional[FieldSpec]:
    """None result == excluded from instrumentation (VOLATILE)."""
    if raw == locking.VOLATILE:
        return None
    if raw is None:
        return FieldSpec("hb", (), "<happens-before>")
    if isinstance(raw, str):
        if raw.startswith("write:"):
            return FieldSpec("write", (raw[len("write:"):],), raw)
        return FieldSpec("guard", (raw,), raw)
    if isinstance(raw, tuple):
        return FieldSpec("guard", tuple(raw), "|".join(raw))
    raise ValueError(f"bad GUARDED_BY spec {raw!r}")


# -------------------------------------------------------------- field shadow

class _FieldState:
    """Per-(object, field) access history."""

    __slots__ = ("wref", "owner", "shared", "w_tid", "w_clock", "w_locks",
                 "w_thread", "w_locknames", "reads")

    def __init__(self, obj, owner: int):
        try:
            self.wref = weakref.ref(obj)
        except TypeError:
            self.wref = None      # unweakrefable: per-test resets cover it
        self.owner = owner
        self.shared = False
        self.w_tid: Optional[int] = None
        self.w_clock = 0
        self.w_locks: frozenset = frozenset()
        self.w_thread = ""
        self.w_locknames = ""
        # tid -> (clock, lockset, thread name); cleared at each write
        self.reads: Dict[int, Tuple[int, frozenset, str]] = {}

    def stale(self, obj) -> bool:
        return self.wref is not None and self.wref() is not obj


class RaceCheck:
    """Vector clocks + locksets + the guarded-by contract, for one armed
    scope (the global ``--sanitize`` session, or one :func:`arm` block)."""

    def __init__(self, tracer, allow: Optional[Set[str]] = None):
        self.tracer = tracer                    # LockTracer: held locksets
        self.rep = Reporter(allow)
        self.violations = self.rep.violations
        self._mu = threading.Lock()             # analysis infra, not core
        self._vc: Dict[int, Dict[int, int]] = {}
        self._sync_vc: Dict[int, Dict[int, int]] = {}   # id(chan) -> VC
        self._sync_pin: Dict[int, object] = {}          # id stability
        self._fields: Dict[Tuple[int, str], _FieldState] = {}
        self._initing: Dict[int, int] = {}      # id(obj) -> __init__ depth
        self.stats_accesses = 0
        self.stats_edges = 0

    # ----------------------------------------------------------- vc helpers
    def _thread_vc(self, t: int) -> Dict[int, int]:
        """The calling thread's VC, lazily initialized from the birth
        snapshot its parent stashed at ``Thread.start``."""
        vc = self._vc.get(t)
        if vc is None:
            vc = self._vc[t] = {}
        cur = threading.current_thread()
        birth = getattr(cur, "_rc_birth", None)
        if birth is not None:
            for k, v in birth.items():
                if vc.get(k, 0) < v:
                    vc[k] = v
            try:
                cur._rc_birth = None
            except AttributeError:
                pass
        if t not in vc:
            vc[t] = 1
        return vc

    @staticmethod
    def _join(dst: Dict[int, int], src: Dict[int, int]) -> None:
        for k, v in src.items():
            if dst.get(k, 0) < v:
                dst[k] = v

    def _channel_publish(self, chan) -> None:
        """release/set side: VC(chan) |= VC(me); tick me."""
        t = tid()
        with self._mu:
            vc = self._thread_vc(t)
            cvc = self._sync_vc.get(id(chan))
            if cvc is None or self._sync_pin.get(id(chan)) is not chan:
                cvc = self._sync_vc[id(chan)] = {}
                self._sync_pin[id(chan)] = chan
            self._join(cvc, vc)
            vc[t] += 1
            self.stats_edges += 1

    def _channel_observe(self, chan) -> None:
        """acquire/wait side: VC(me) |= VC(chan)."""
        t = tid()
        with self._mu:
            vc = self._thread_vc(t)
            cvc = self._sync_vc.get(id(chan))
            if cvc is not None and self._sync_pin.get(id(chan)) is chan:
                self._join(vc, cvc)
                self.stats_edges += 1

    # --------------------------------------------------- lockcheck forwards
    def on_acquire(self, lock) -> None:
        if not _enter_hook():
            return
        try:
            self._channel_observe(lock)
        finally:
            _exit_hook()

    def on_release(self, lock) -> None:
        if not _enter_hook():
            return
        try:
            self._channel_publish(lock)
        finally:
            _exit_hook()

    # ------------------------------------------------------- thread + event
    def on_thread_start(self, thread) -> None:
        if not _enter_hook():
            return
        try:
            t = tid()
            with self._mu:
                vc = self._thread_vc(t)
                thread._rc_birth = dict(vc)
                vc[t] += 1
                self.stats_edges += 1
        finally:
            _exit_hook()

    def on_thread_join(self, thread) -> None:
        ct = thread.ident
        if ct is None or not _enter_hook():
            return
        try:
            t = tid()
            with self._mu:
                vc = self._thread_vc(t)
                cvc = self._vc.get(ct)
                if cvc is not None and ct != t:
                    self._join(vc, cvc)
                    self.stats_edges += 1
        finally:
            _exit_hook()

    def on_event_set(self, event) -> None:
        if not _enter_hook():
            return
        try:
            self._channel_publish(event)
        finally:
            _exit_hook()

    def on_event_wait(self, event) -> None:
        if not _enter_hook():
            return
        try:
            self._channel_observe(event)
        finally:
            _exit_hook()

    # --------------------------------------------------- construction guard
    def note_init_enter(self, obj) -> None:
        with self._mu:
            self._initing[id(obj)] = self._initing.get(id(obj), 0) + 1

    def note_init_exit(self, obj) -> None:
        with self._mu:
            d = self._initing.get(id(obj), 0) - 1
            if d <= 0:
                self._initing.pop(id(obj), None)
            else:
                self._initing[id(obj)] = d

    # -------------------------------------------------------- field accesses
    def _guard_held(self, obj, spec: FieldSpec) -> bool:
        for gattr in spec.guards:
            try:
                lk = object.__getattribute__(obj, gattr)
            except AttributeError:
                continue
            if isinstance(lk, threading.Condition):
                lk = lk._lock
            owned = getattr(lk, "_is_owned", None)
            if owned is None:
                return True       # untraced primitive: cannot judge — pass
            if owned():
                return True
        return False

    def on_field(self, obj, cls: type, name: str, spec: FieldSpec,
                 is_write: bool) -> None:
        if not is_write and spec.mode == "write":
            return                # lock-free reads by design
        if not _enter_hook():
            return
        try:
            self._on_field(obj, cls, name, spec, is_write)
        finally:
            _exit_hook()

    def _on_field(self, obj, cls: type, name: str, spec: FieldSpec,
                  is_write: bool) -> None:
        t = tid()
        held = self.tracer.held_locks()
        lockset = frozenset(id(l) for l in held)
        with self._mu:
            if self._initing.get(id(obj)):
                return            # under construction: thread-exclusive
            self.stats_accesses += 1
            vc = self._thread_vc(t)
            clock = vc[t]
            key = (id(obj), name)
            st = self._fields.get(key)
            if st is None or st.stale(obj):
                st = self._fields[key] = _FieldState(obj, t)
            if st.owner != t:
                st.shared = True
            me = tname()
            cfield = f"{cls.__name__}.{name}"

            def hb(atid: int, aclock: int) -> bool:
                return vc.get(atid, 0) >= aclock

            # epoch + lockset analysis (the hybrid rule)
            if st.w_tid is not None and st.w_tid != t \
                    and not hb(st.w_tid, st.w_clock) \
                    and not (st.w_locks & lockset):
                code = "RC001" if is_write else "RC002"
                kind = "write-write" if is_write else "read-write"
                self.rep.flag(
                    code,
                    f"{kind} race on {cfield}: {me} "
                    f"({self._locknames(held)}) vs write by {st.w_thread} "
                    f"({st.w_locknames}); no happens-before edge orders "
                    f"them",
                    key=(code, cls.__name__, name))
            if is_write:
                for rt, (rclock, rlocks, rthread) in st.reads.items():
                    if rt != t and not hb(rt, rclock) \
                            and not (rlocks & lockset):
                        self.rep.flag(
                            "RC002",
                            f"read-write race on {cfield}: write by {me} "
                            f"({self._locknames(held)}) vs read by "
                            f"{rthread}; no happens-before edge orders "
                            f"them",
                            key=("RC002", cls.__name__, name))
                        break

            # guarded-by discipline (RC003): only once shared between
            # threads, and only when genuinely unordered against another
            # thread's accesses — single-threaded setup and post-join
            # teardown reads stay clean.
            if st.shared and spec.mode in ("guard", "write") \
                    and (spec.mode == "guard" or is_write):
                others: List[Tuple[int, int]] = []
                if st.w_tid is not None and st.w_tid != t:
                    others.append((st.w_tid, st.w_clock))
                others.extend((rt, r[0]) for rt, r in st.reads.items()
                              if rt != t)
                if any(not hb(at, ac) for at, ac in others) \
                        and not self._guard_held(obj, spec):
                    verb = "written" if is_write else "read"
                    self.rep.flag(
                        "RC003",
                        f"{cfield} {verb} by {me} without its declared "
                        f"guard ({spec.display}) held "
                        f"(held: {self._locknames(held)})",
                        key=("RC003", cls.__name__, name))

            # record this access
            if is_write:
                st.w_tid, st.w_clock = t, clock
                st.w_locks, st.w_thread = lockset, me
                st.w_locknames = self._locknames(held)
                st.reads.clear()
            else:
                st.reads[t] = (clock, lockset, me)

    @staticmethod
    def _locknames(held) -> str:
        return "locks {" + ", ".join(l.name for l in held) + "}"

    # ------------------------------------------------------------- per-test
    def begin_test(self) -> None:
        """Fresh field shadows and dedup keys (clocks/edges persist —
        threads outlive tests)."""
        with self._mu:
            self._fields.clear()
        self.rep.reset_dedup()


# ----------------------------------------------------------- instrumentation

# cls -> (orig __getattribute__, orig __setattr__, orig __init__)
_instrumented: Dict[type, tuple] = {}


def instrument(cls: type) -> bool:
    """Patch ``cls`` so accesses to its declared fields are routed to the
    active detector.  Idempotent; returns True when the class has
    checkable declarations."""
    if cls in _instrumented:
        return True
    specs: Dict[str, FieldSpec] = {}
    for fname, raw in locking.guards(cls).items():
        sp = parse_spec(raw)
        if sp is not None:
            specs[fname] = sp
    if not specs:
        return False
    for base in cls.__mro__[1:]:
        if base in _instrumented:
            # the inherited (instrumented) methods already intercept the
            # ancestor's declared fields — wrapping them again here would
            # double-report every access; only newly-declared names need
            # a subclass wrapper
            inherited = locking.guards(base)
            specs = {f: s for f, s in specs.items() if f not in inherited}
            break
    if not specs:
        return True                    # fully covered by an ancestor's wrap
    names = frozenset(specs)
    orig_get = cls.__getattribute__
    orig_set = cls.__setattr__
    orig_init = cls.__init__

    def rc_getattribute(self, name, _names=names, _orig=orig_get,
                        _cls=cls, _specs=specs):
        if name in _names:
            rc = _active
            if rc is not None:
                rc.on_field(self, _cls, name, _specs[name], False)
        return _orig(self, name)

    def rc_setattr(self, name, value, _names=names, _orig=orig_set,
                   _cls=cls, _specs=specs):
        if name in _names:
            rc = _active
            if rc is not None:
                rc.on_field(self, _cls, name, _specs[name], True)
        _orig(self, name, value)

    def rc_init(self, *a, _orig=orig_init, **kw):
        rc = _active
        if rc is None:
            return _orig(self, *a, **kw)
        rc.note_init_enter(self)
        try:
            return _orig(self, *a, **kw)
        finally:
            rc.note_init_exit(self)

    cls.__getattribute__ = rc_getattribute
    cls.__setattr__ = rc_setattr
    cls.__init__ = rc_init
    _instrumented[cls] = (orig_get, orig_set, orig_init)
    return True


def deinstrument(cls: type) -> None:
    orig = _instrumented.pop(cls, None)
    if orig is not None:
        cls.__getattribute__, cls.__setattr__, cls.__init__ = orig


#: core modules whose GUARDED_BY-bearing classes install_core instruments;
#: bare names resolve under ``repro.core``, dotted names are absolute
CORE_MODULES = ("api", "log", "cleanup", "pager", "router", "namespace",
                "readcache", "drain",
                "repro.obs.metrics", "repro.obs.flight")


def install_core() -> List[type]:
    """Instrument every declared class in the core modules (idempotent)."""
    import importlib
    done: List[type] = []
    for modname in CORE_MODULES:
        mod = importlib.import_module(
            modname if "." in modname else f"repro.core.{modname}")
        for obj in list(vars(mod).values()):
            if isinstance(obj, type) and obj.__module__ == mod.__name__ \
                    and locking.guards(obj):
                if instrument(obj):
                    done.append(obj)
    return done


def uninstall_core() -> None:
    for cls in list(_instrumented):
        deinstrument(cls)


# ---------------------------------------------------- thread/event HB hooks

_thread_orig: Dict[str, object] = {}


def install_thread_hooks() -> None:
    """Patch ``Thread.start``/``join`` and ``Event.set``/``wait`` so the
    detector sees the engine's thread-lifecycle and hand-off edges.
    No-ops (one attribute load) while no detector is active."""
    if _thread_orig:
        return
    _thread_orig["start"] = threading.Thread.start
    _thread_orig["join"] = threading.Thread.join
    _thread_orig["set"] = threading.Event.set
    _thread_orig["wait"] = threading.Event.wait

    def start(self):
        rc = _active
        if rc is not None:
            rc.on_thread_start(self)
        return _thread_orig["start"](self)

    def join(self, timeout=None):
        r = _thread_orig["join"](self, timeout)
        rc = _active
        if rc is not None and not self.is_alive():
            rc.on_thread_join(self)
        return r

    def ev_set(self):
        rc = _active
        if rc is not None:
            rc.on_event_set(self)      # publish BEFORE waking waiters
        return _thread_orig["set"](self)

    def ev_wait(self, timeout=None):
        ok = _thread_orig["wait"](self, timeout)
        rc = _active
        if ok and rc is not None:
            rc.on_event_wait(self)
        return ok

    threading.Thread.start = start
    threading.Thread.join = join
    threading.Event.set = ev_set
    threading.Event.wait = ev_wait


def uninstall_thread_hooks() -> None:
    if not _thread_orig:
        return
    threading.Thread.start = _thread_orig["start"]
    threading.Thread.join = _thread_orig["join"]
    threading.Event.set = _thread_orig["set"]
    threading.Event.wait = _thread_orig["wait"]
    _thread_orig.clear()


# ------------------------------------------------------------------ arm()

@contextlib.contextmanager
def arm(tracer=None, allow: Optional[Set[str]] = None):
    """Arm a fresh :class:`RaceCheck` for the duration of the block.

    Works standalone (a temporary ``LockTracer`` is registered with
    ``locking`` so engine locks built inside the block are traced) and
    under ``--sanitize`` (attaches to the session tracer but swaps in a
    *local* detector, so intentional planted races never reach the
    session's violation sink — the ``pmcheck.attach`` trick).
    """
    from repro.analysis.lockcheck import LockTracer

    own_tracer = False
    if tracer is None:
        tracer = locking._tracer
        if tracer is None:
            tracer = LockTracer()
            locking.set_tracer(tracer)
            own_tracer = True
    rc = RaceCheck(tracer, allow=allow)
    install_core()
    install_thread_hooks()
    prev_active = _active
    prev_race = getattr(tracer, "race", None)
    tracer.race = rc
    set_active(rc)
    try:
        yield rc
    finally:
        set_active(prev_active)
        tracer.race = prev_race
        if own_tracer:
            locking.set_tracer(None)
        if prev_active is None:
            from repro.analysis import sanitize
            if sanitize.state_or_none() is None:
                # plain run: leave no instrumentation overhead behind
                uninstall_core()
                uninstall_thread_hooks()
