"""Shared plumbing for the runtime checkers (pmcheck, lockcheck,
racecheck): violation records, a deduplicating thread-safe reporter, and
the thread-identity helpers every checker needs.

The three checkers attach from the outside and must never raise inside
an engine thread (a drain thread that dies hangs the pool), so they all
follow the same record-don't-raise discipline.  This module is that
discipline, factored once:

* :class:`Violation` — one finding: code, human message, the thread that
  produced it (captured at flag time — by teardown the thread is gone).
* :class:`Reporter` — append-only violation sink with an ``allow`` set
  (suppression by code) and first-occurrence dedup by an arbitrary
  hashable key, under its own raw mutex (NOT a traced lock: checkers run
  inside traced-lock critical sections and must not re-enter the
  tracer).
* :func:`tid` / :func:`tname` — the ``threading.get_ident()`` /
  current-thread-name pair previously re-derived in each checker.
"""
from __future__ import annotations

import threading
from typing import Hashable, List, Optional, Set


def tid() -> int:
    """Identity of the calling thread (stable while the thread lives)."""
    return threading.get_ident()


def tname() -> str:
    """Best-effort human name of the calling thread."""
    return threading.current_thread().name


class Violation:
    """One checker finding."""

    __slots__ = ("code", "msg", "thread")

    def __init__(self, code: str, msg: str, thread: Optional[str] = None):
        self.code = code
        self.msg = msg
        self.thread = thread if thread is not None else tname()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"{self.code}[{self.thread}] {self.msg}"

    def __str__(self) -> str:
        return f"{self.code}: {self.msg} (thread {self.thread})"


class Reporter:
    """Thread-safe, deduplicating violation sink.

    ``allow`` suppresses whole codes; ``flag`` drops repeats of the same
    ``key`` (default: the ``(code, msg)`` pair) so a racy loop produces
    one report, not thousands.  Uses a raw ``threading.Lock`` on
    purpose — see the module docstring.
    """

    def __init__(self, allow: Optional[Set[str]] = None):
        self.allow: Set[str] = set(allow or ())
        self.violations: List[Violation] = []
        self._seen: Set[Hashable] = set()
        self._mu = threading.Lock()

    def flag(self, code: str, msg: str,
             key: Optional[Hashable] = None) -> bool:
        """Record one violation; returns True when it was new (not
        suppressed, not a dup)."""
        if code in self.allow:
            return False
        k = key if key is not None else (code, msg)
        with self._mu:
            if k in self._seen:
                return False
            self._seen.add(k)
            self.violations.append(Violation(code, msg))
            return True

    def mark(self) -> int:
        """Current length, for per-test slicing (``violations[mark:]``)."""
        with self._mu:
            return len(self.violations)

    def since(self, mark: int) -> List[Violation]:
        with self._mu:
            return list(self.violations[mark:])

    def reset_dedup(self) -> None:
        """Forget dedup keys (each test deserves its own first report)."""
        with self._mu:
            self._seen.clear()
