"""Wire the runtime checkers into a live process (``pytest --sanitize``).

:func:`install` does three things, all before any engine object exists:

* registers a :class:`repro.analysis.lockcheck.LockTracer` with
  :mod:`repro.core.locking`, so every lock subsequently built by the
  ``make_*`` factories is a hierarchy-checked ``TracedLock``;
* patches the :class:`repro.core.nvmm.NVMM` *class* so each instance gets
  a :class:`repro.analysis.pmcheck.PMCheck` shadow at construction and
  every ``store``/``pwb``/``pfence``/``psync``/``crash`` is traced — at
  the base class, so the crash-fuse subclasses the sweeps use (they
  override these methods and call ``super()``) are covered too;
* patches :class:`repro.core.log.NVLog` to bind each adopted region's
  :class:`~repro.core.policy.Policy` into its shadow (commit-point
  detection needs the layout), and the backend
  :class:`repro.storage.tiers.TierFile` I/O entry points to feed
  ``lockcheck``'s I/O-under-shard-lock rule;
* arms :mod:`repro.analysis.racecheck`: the ``GUARDED_BY``-declared
  classes in ``repro.core`` are instrumented, thread/Event lifecycle
  hooks installed, and a session-wide :class:`~repro.analysis.racecheck.
  RaceCheck` attached to the lock tracer (lock edges feed its vector
  clocks; its RC001–RC003 reports fail the test like any other).

The pytest fixture in ``tests/conftest.py`` calls :func:`begin_test` /
:func:`end_test` around every test and fails the test on any accumulated
violation (raising inside a drain thread would hang the pool instead).
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis import racecheck
from repro.analysis.lockcheck import LockTracer
from repro.analysis.pmcheck import PMCheck
from repro.analysis.racecheck import RaceCheck
from repro.core.policy import CACHELINE

_state: Optional["SanitizeState"] = None


class SanitizeState:
    def __init__(self):
        self.tracer = LockTracer()
        self.race = RaceCheck(self.tracer)
        self.tracer.race = self.race
        self.pmchecks: List[PMCheck] = []   # created since begin_test()
        self.nvlogs: list = []              # NVLogs created since begin_test()
        self._lc_mark = 0
        self._rc_mark = 0
        self._orig = {}

    # ------------------------------------------------------------ per-test
    def begin_test(self) -> None:
        self.pmchecks.clear()
        self.nvlogs.clear()
        self._lc_mark = self.tracer._rep.mark()
        self.race.begin_test()
        self._rc_mark = self.race.rep.mark()

    def end_test(self, allow_full_scan: bool = False) -> List[str]:
        errors: List[str] = []
        for pm in self.pmchecks:
            errors.extend(repr(v) for v in pm.violations)
        # the class-level graph is cumulative across tests on purpose: two
        # tests driving opposite orders through the same code is a latent
        # deadlock even if no single run interleaves into it (LC003 dedups,
        # so an old cycle is reported once, at the test that closed it)
        self.tracer.check_cycles()
        errors.extend(str(v) for v in self.tracer._rep.since(self._lc_mark))
        errors.extend(str(v) for v in self.race.rep.since(self._rc_mark))
        if not allow_full_scan:
            for log in self.nvlogs:
                if log.stats_full_scans:
                    errors.append(
                        f"FS001: NVLog performed {log.stats_full_scans} full "
                        f"log scan(s) (scan_all_committed is recovery/"
                        f"diagnostic-only; mark the test full_scan_ok if "
                        f"intentional)")
        self.pmchecks.clear()
        self.nvlogs.clear()
        return errors


def state_or_none() -> Optional[SanitizeState]:
    return _state


def install() -> SanitizeState:
    """Idempotent; returns the active state."""
    global _state
    if _state is not None:
        return _state
    st = SanitizeState()

    from repro.core import locking
    locking.set_tracer(st.tracer)

    # ------------------------------------------------------ race detector
    racecheck.install_core()
    racecheck.install_thread_hooks()
    racecheck.set_active(st.race)

    # ---------------------------------------------------- NVMM class hooks
    from repro.core.nvmm import NVMM
    orig = st._orig
    orig["init"] = NVMM.__init__
    orig["store"] = NVMM.store
    orig["pwb"] = NVMM.pwb
    orig["pfence"] = NVMM.pfence
    orig["psync"] = NVMM.psync
    orig["crash"] = NVMM.crash

    def init(self, size, *, track=False):
        orig["init"](self, size, track=track)
        self._pm = PMCheck(self)
        st.pmchecks.append(self._pm)

    def store(self, off, data):
        self._pm.on_store(off, data)
        return orig["store"](self, off, data)

    def pwb(self, off, n=CACHELINE):
        self._pm.on_pwb(off, n)
        return orig["pwb"](self, off, n)

    def pfence(self):
        self._pm.on_fence("pfence")
        return orig["pfence"](self)

    def psync(self):
        self._pm.on_fence("psync")
        return orig["psync"](self)

    def crash(self, choose_evicted=None):
        self._pm.on_crash()
        return orig["crash"](self, choose_evicted)

    NVMM.__init__ = init
    NVMM.store = store
    NVMM.pwb = pwb
    NVMM.pfence = pfence
    NVMM.psync = psync
    NVMM.crash = crash

    # ------------------------------------------- layout binding via NVLog
    from repro.core.log import NVLog
    orig["nvlog_init"] = NVLog.__init__

    def nvlog_init(self, nvmm, policy, **kw):
        pm = getattr(nvmm, "_pm", None)
        if pm is not None:
            pm.bind(policy)               # before format() stores anything
        orig["nvlog_init"](self, nvmm, policy, **kw)
        st.nvlogs.append(self)

    NVLog.__init__ = nvlog_init

    # --------------------------------------------------- backend I/O hooks
    from repro.storage.tiers import TierFile
    for name in ("pwrite", "pwritev", "fsync"):
        orig["tier_" + name] = getattr(TierFile, name)

        def make(name, fn):
            def wrapper(self, *a, **kw):
                st.tracer.on_backend_io(name, getattr(self, "path", ""))
                return fn(self, *a, **kw)
            return wrapper

        setattr(TierFile, name, make(name, orig["tier_" + name]))

    _state = st
    return st


def uninstall() -> None:
    global _state
    if _state is None:
        return
    from repro.core import locking
    from repro.core.nvmm import NVMM
    from repro.core.log import NVLog
    from repro.storage.tiers import TierFile
    o = _state._orig
    locking.set_tracer(None)
    racecheck.set_active(None)
    racecheck.uninstall_core()
    racecheck.uninstall_thread_hooks()
    NVMM.__init__, NVMM.store, NVMM.pwb = o["init"], o["store"], o["pwb"]
    NVMM.pfence, NVMM.psync, NVMM.crash = o["pfence"], o["psync"], o["crash"]
    NVLog.__init__ = o["nvlog_init"]
    for name in ("pwrite", "pwritev", "fsync"):
        setattr(TierFile, name, o["tier_" + name])
    _state = None
