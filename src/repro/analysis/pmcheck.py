"""pmemcheck-style persistence-ordering sanitizer for the simulated NVMM.

A :class:`PMCheck` instance shadows one :class:`repro.core.nvmm.NVMM`
region at cacheline granularity, mirroring the crash model's state
machine (dirty -> flush-requested -> durable) *independently* of the
region's ``track`` flag, and checks the three commit protocols the engine
runs over the region:

* **log group commit** — the 8-byte ``cg = CG_HEAD`` store on a group
  head (``LogShard.append``),
* **frame flip** — the single-cacheline ``_FR`` header store of a mapped
  paged frame (``PagedRegion.frame_write`` / ``truncate_frame``),
* **route/manifest record** — the CRC'd ``_RT_HDR`` store at
  ``route_base`` (``EpochRouter._persist_locked``).

Error codes (collected in :attr:`PMCheck.violations`; the ``--sanitize``
pytest fixture fails a test that accumulated any):

* ``PM001`` — a commit-point store was issued while one or more covered
  payload cachelines were not yet fenced durable (dirty, or pwb'd but no
  fence drained them).  This is the "missing pwb / missing pfence before
  the commit flag" bug class: invisible to crash sampling until the one
  crash that loses exactly those lines.
* ``PM002`` — the committing thread stored into its own commit's covered
  region between the commit-point store and the psync that seals it: the
  store rides the commit's durability attribution without being ordered
  by it.  Scoped to the owner thread: a cross-thread overlap is a legal
  interleaving (the drain retires backend-durable entries without waiting
  for an in-flight commit's psync).
* ``PM004`` — the committing thread issued its sealing fence while the
  commit flag's own cacheline was still dirty (commit store never
  pwb'd): the psync returns with the commit flag not durable.

Perf diagnostics (counted, never errors):

* ``diag_redundant_pwb``  — a ``pwb`` covering no dirty line (the lines
  were already flush-requested or clean): wasted ``clwb`` traffic.
* ``diag_empty_fence``    — a ``pfence``/``psync`` with nothing
  flush-requested: back-to-back fence.

Suppression: pass ``allow={"PM001", ...}`` to :class:`PMCheck` (or use
``pmcheck.suppress("PM001")`` around a block) for protocol code that is
deliberately outside the model — nothing in ``repro.core`` needs it.

Attachment: :func:`attach` wires a PMCheck into one NVMM instance's bound
methods (planted-bug tests use this).  Under ``pytest --sanitize`` the
:mod:`repro.analysis.sanitize` module instead patches the ``NVMM`` base
class so subclass overrides (the crash-fuse NVMMs call ``super()``) are
covered, and binds the region layout when an ``NVLog`` adopts the region.
"""
from __future__ import annotations

import struct
import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.trace import Reporter, Violation, tid
from repro.core.policy import CACHELINE, FRAME_HDR, ROUTE_ENT, ROUTE_HDR, Policy

_U64 = struct.Struct("<Q")

# entry header layout (repro.core.log._HDR): cg, seq, off, fdid, length, nfollow, crc
_HDR = struct.Struct("<QQQIIII")
HDR_SIZE = 48
CG_HEAD = 1
# frame header layout (repro.core.pager._FR): state, slot, page_no, seq, fdid, length, crc
_FR = struct.Struct("<IIQQIII")
FR_MAPPED = 1
# route record header (repro.core.router._RT_HDR): epoch, count, crc
_RT_HDR = struct.Struct("<QII")

_DIRTY = 1
_REQUESTED = 2


# violation records now come from the shared checker plumbing; the old
# name stays importable for the planted-bug suite and external tooling
PMViolation = Violation


class _Window:
    """One open commit: covered payload byte-ranges sealed by the next
    fence (issued by the owner thread) that drains the commit line."""
    __slots__ = ("kind", "commit_off", "commit_len", "covered", "owner")

    def __init__(self, kind: str, commit_off: int, commit_len: int,
                 covered: List[Tuple[int, int]]):
        self.kind = kind
        self.commit_off = commit_off
        self.commit_len = commit_len
        self.covered = covered            # [(start, end)) byte ranges
        self.owner = tid()

    @property
    def commit_line(self) -> int:
        return self.commit_off // CACHELINE


class PMCheck:
    """Shadow state machine + commit-protocol checks for one NVMM."""

    def __init__(self, nvmm, policy: Optional[Policy] = None,
                 allow: Optional[Set[str]] = None):
        self.nvmm = nvmm
        self.policy: Optional[Policy] = None
        self._mu = threading.Lock()       # analysis infra, not a core lock
        self._lines: Dict[int, int] = {}  # line -> _DIRTY | _REQUESTED
        self._windows: List[_Window] = []
        self._rep = Reporter(allow)       # shared sink (dedup by code+msg)
        self.violations = self._rep.violations
        self.allow = self._rep.allow
        self.diag_redundant_pwb = 0
        self.diag_empty_fence = 0
        self.stats_commits = 0
        if policy is not None:
            self.bind(policy)

    # -------------------------------------------------------------- layout
    def bind(self, policy: Policy) -> None:
        """Adopt the region layout; commit-point detection needs it (state
        tracking alone works unbound)."""
        with self._mu:
            self.policy = policy
            self._shard_bytes = policy.entries_per_shard * policy.entry_size
            self._windows.clear()

    # ------------------------------------------------------------- reports
    def _flag(self, code: str, msg: str) -> None:
        self._rep.flag(code, msg)

    def reset(self) -> None:
        with self._mu:
            self._lines.clear()
            self._windows.clear()

    def summary(self) -> dict:
        return {
            "violations": [repr(v) for v in self.violations],
            "commits_checked": self.stats_commits,
            "diag_redundant_pwb": self.diag_redundant_pwb,
            "diag_empty_fence": self.diag_empty_fence,
        }

    def __deepcopy__(self, memo):
        """Deepcopying a shadowed NVMM (the crash-image snapshot idiom in
        the recovery tests) gives the copy a *fresh* shadow: raw locks
        don't survive ``copy.deepcopy``, and the copy's future stores are
        not this shadow's to judge.  The copied image is a crashed one, so
        starting all-durable is exactly right.  Under an active
        ``--sanitize`` session the new shadow registers with it, keeping
        the copy's violations visible to the per-test guard."""
        nvmm = memo.get(id(self.nvmm), self.nvmm)
        pm = PMCheck(nvmm, policy=self.policy, allow=set(self.allow))
        memo[id(self)] = pm
        from repro.analysis import sanitize
        st = sanitize.state_or_none()
        if st is not None:
            st.pmchecks.append(pm)
        return pm

    # ------------------------------------------------------ state helpers
    @staticmethod
    def _lines_of(off: int, n: int):
        return range(off // CACHELINE, (off + max(n, 1) - 1) // CACHELINE + 1)

    def _undurable_lines(self, ranges: List[Tuple[int, int]]) -> List[int]:
        bad = []
        for s, e in ranges:
            for line in self._lines_of(s, e - s):
                if line in self._lines:
                    bad.append(line)
        return bad

    # ------------------------------------------------- commit-point detect
    def _detect_commit(self, off: int, data) -> Optional[_Window]:
        pol = self.policy
        if pol is None:
            return None
        n = len(data)
        buf = self.nvmm._buf
        if n == 8 and off >= pol.entries_base \
                and (off - pol.entries_base) % pol.entry_size == 0 \
                and _U64.unpack(bytes(data[:8]))[0] == CG_HEAD:
            # log group head commit: cover head header+payload and every
            # follower entry (headers at the time of the commit store)
            sid = (off - pol.entries_base) // self._shard_bytes
            base = pol.shard_base(sid)
            slot = (off - base) // pol.entry_size
            nslots = pol.entries_per_shard
            _cg, _seq, _foff, _fdid, length, nfollow, _crc = _HDR.unpack_from(
                buf, off)
            covered = [(off, off + HDR_SIZE + length)]
            for j in range(1, nfollow + 1):
                eoff = base + ((slot + j) % nslots) * pol.entry_size
                flen = _HDR.unpack_from(buf, eoff)[4]
                covered.append((eoff, eoff + HDR_SIZE + flen))
            return _Window("log", off, 8, covered)
        if n == _FR.size and pol.page_frames \
                and pol.page_base <= off < pol.entries_base \
                and (off - pol.page_base) % pol.frame_size == 0:
            state, slot, _pno, _seq, _fdid, length, _crc = _FR.unpack(
                bytes(data[:_FR.size]))
            if state != FR_MAPPED:
                return None               # invalidate/format, not a commit
            doff = off + FRAME_HDR + slot * pol.page_size
            return _Window("frame", off, n, [(doff, doff + length)])
        if n == ROUTE_HDR and off == pol.route_base:
            _epoch, count, _crc = _RT_HDR.unpack(bytes(data[:ROUTE_HDR]))
            payload = (off + ROUTE_HDR, off + ROUTE_HDR + count * ROUTE_ENT)
            return _Window("route", off, n,
                           [payload] if count else [])
        return None

    # ----------------------------------------------------- traced NVMM ops
    def on_store(self, off: int, data) -> None:
        """Called BEFORE the underlying store is applied."""
        n = len(data)
        me = tid()
        with self._mu:
            for w in self._windows:
                # PM002 polices protocol order on the COMMITTING thread only:
                # another thread overlapping the window is legitimate (the
                # drain retires backend-durable entries without waiting for
                # the in-flight commit's psync — its own pfence drains the
                # writer's pwb-requested commit line, so durability holds).
                if w.owner != me:
                    continue
                for s, e in w.covered:
                    if off < e and off + n > s \
                            and not (off >= w.commit_off
                                     and off + n <= w.commit_off + w.commit_len):
                        self._flag("PM002",
                                   f"store [{off},{off + n}) lands inside the "
                                   f"open {w.kind} commit at {w.commit_off} "
                                   f"before its sealing psync")
                        break
            w = self._detect_commit(off, data)
            if w is not None:
                self.stats_commits += 1
                bad = self._undurable_lines(w.covered)
                if bad:
                    self._flag("PM001",
                               f"{w.kind} commit store at {off} with "
                               f"{len(bad)} covered cacheline(s) not fenced "
                               f"durable (lines {bad[:8]})")
                self._windows.append(w)
            for line in self._lines_of(off, n):
                self._lines[line] = _DIRTY

    def on_pwb(self, off: int, n: int = CACHELINE) -> None:
        with self._mu:
            moved = 0
            for line in self._lines_of(off, n):
                if self._lines.get(line) == _DIRTY:
                    self._lines[line] = _REQUESTED
                    moved += 1
            if moved == 0:
                self.diag_redundant_pwb += 1

    def on_fence(self, kind: str) -> None:
        me = tid()
        with self._mu:
            drained = {l for l, st in self._lines.items() if st == _REQUESTED}
            if not drained:
                self.diag_empty_fence += 1
            for line in drained:
                del self._lines[line]
            still_open = []
            for w in self._windows:
                if w.commit_line in drained:
                    continue              # sealed
                if w.owner == me and self._lines.get(w.commit_line) == _DIRTY:
                    self._flag("PM004",
                               f"{kind} by the committing thread with the "
                               f"{w.kind} commit flag at {w.commit_off} "
                               f"still dirty (commit store never pwb'd)")
                still_open.append(w)
            self._windows = still_open

    def on_crash(self) -> None:
        """Power loss: the volatile view collapses onto the durable shadow;
        every in-flight commit window dies with it."""
        self.reset()


# ---------------------------------------------------------------------------
# instance-level attachment (planted-bug tests; sanitize.py patches the
# NVMM *class* instead so crash-fuse subclasses are covered)

def attach(nvmm, policy: Optional[Policy] = None,
           allow: Optional[Set[str]] = None) -> PMCheck:
    """Wrap one NVMM instance's ``store``/``pwb``/``pfence``/``psync``/
    ``crash`` bound methods with a fresh :class:`PMCheck`.  Only sound for
    instances whose class does not override those methods (the crash-fuse
    subclasses do — use :mod:`repro.analysis.sanitize` for them)."""
    pm = PMCheck(nvmm, policy=policy, allow=allow)
    from repro.analysis import sanitize
    if sanitize.state_or_none() is not None and hasattr(nvmm, "_pm"):
        # ``sanitize.install()``'s class-level hooks already route every
        # store/pwb/fence through ``nvmm._pm`` — rebind that slot instead of
        # stacking instance wrappers on top (which would deliver every event
        # twice: once from the wrapper, once from the patched class method).
        nvmm._pm = pm
        return pm
    orig_store, orig_pwb = nvmm.store, nvmm.pwb
    orig_pfence, orig_psync, orig_crash = nvmm.pfence, nvmm.psync, nvmm.crash

    def store(off, data):
        pm.on_store(off, data)
        return orig_store(off, data)

    def pwb(off, n=CACHELINE):
        pm.on_pwb(off, n)
        return orig_pwb(off, n)

    def pfence():
        pm.on_fence("pfence")
        return orig_pfence()

    def psync():
        pm.on_fence("psync")
        return orig_psync()

    def crash(choose_evicted=None):
        pm.on_crash()
        return orig_crash(choose_evicted)

    nvmm.store, nvmm.pwb = store, pwb
    nvmm.pfence, nvmm.psync, nvmm.crash = pfence, psync, crash
    nvmm._pm = pm
    return pm
