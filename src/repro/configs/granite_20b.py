"""granite-20b [dense, code] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 [arXiv:2405.04324].  GPT-BigCode lineage: learned absolute
positions (table sized for the 32k decode cell)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    pos="learned", max_positions=32768, remat="full",
)

SMOKE = ModelConfig(
    arch="granite-20b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=256, head_dim=16,
    pos="learned", max_positions=128, attn_block=32,
)
