"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 [arXiv:2409.12191].  M-RoPE with (t,h,w) sections (16,24,24);
the vision tower is a STUB per the assignment — input_specs provides token
ids plus 3-axis position ids (patch embeddings would enter pre-projected)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    pos="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6, pad_heads_to=16,
)

SMOKE = ModelConfig(
    arch="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=32,
    pos="mrope", mrope_sections=(4, 6, 6), rope_theta=1e6, attn_block=32,
)
