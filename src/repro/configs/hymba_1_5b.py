"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 [arXiv:2411.13676].  Parallel attention + mamba
heads per block; sliding-window attention except 3 global layers (first,
middle, last, per the paper).  Hymba's meta-tokens are omitted (orthogonal
to this framework's technique; noted in DESIGN.md)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    swa_window=1024, global_layers=(0, 15, 31), pad_heads_to=16,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    ssm_pad_heads_to=16,
)

SMOKE = ModelConfig(
    arch="hymba-1.5b-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    swa_window=16, global_layers=(1,),
    ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=16,
    attn_block=32,
)
