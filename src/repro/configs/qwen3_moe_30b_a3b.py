"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) expert
d_ff=768, vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
Qwen3 decouples head_dim (128) from d_model/n_heads."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=0, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, d_expert=768,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    arch="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=0, vocab=256, head_dim=32,
    n_experts=8, top_k=2, d_expert=48,
    rope_theta=1e6, moe_group=64, attn_block=32,
)
