"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

ARCHS = {
    "llama3.2-1b": "llama3_2_1b",
    "minicpm3-4b": "minicpm3_4b",
    "granite-20b": "granite_20b",
    "minitron-8b": "minitron_8b",
    "whisper-small": "whisper_small",
    "arctic-480b": "arctic_480b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mamba2-780m": "mamba2_780m",
}


def _mod(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


def all_archs():
    return list(ARCHS)
