"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

Memory posture (the largest assigned model, ~470 B params): bf16 params and
bf16 Adam moments so that train state fits the v5e fleet; see DESIGN.md."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    n_experts=128, top_k=2, d_expert=4864, dense_residual=True, pad_heads_to=16,
    param_dtype="bfloat16", remat="full",
)

SMOKE = ModelConfig(
    arch="arctic-480b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=256, head_dim=16,
    n_experts=8, top_k=2, d_expert=96, dense_residual=True,
    moe_group=64, attn_block=32,
)
