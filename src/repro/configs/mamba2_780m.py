"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 [arXiv:2405.21060].  SSD with expand 2 (d_inner 3072),
head_dim 64 (48 heads), conv 4, chunk 256."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-780m", family="ssm", attn_kind="none",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, head_dim=1,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    ssm_pad_heads_to=16,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch="mamba2-780m-smoke", family="ssm", attn_kind="none",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=256, head_dim=1,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=16,
    tie_embeddings=True,
)
