"""Assigned input-shape cells and their ShapeDtypeStruct input specs.

LM shapes are seq_len x global_batch; decode_* / long_* lower ``serve_step``
(one token against a seq_len cache), not ``train_step``.  ``long_500k``
requires sub-quadratic attention: it runs for the SSM/hybrid archs and is
SKIPPED for pure full-attention archs (recorded per cell; see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import build


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

WHISPER_ENC_LEN = 1500      # cross-attention length for whisper decode cells


def applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("pure full-attention arch: 524k-token decode requires "
                       "sub-quadratic attention (skip per assignment)")
    return True, ""


def scale_shape(shape: Shape, *, seq: int = 0, batch: int = 0) -> Shape:
    """Reduced variant for smoke tests."""
    return Shape(shape.name, shape.kind, seq or shape.seq, batch or shape.batch)


def input_specs(cfg: ModelConfig, shape: Shape):
    """ShapeDtypeStruct stand-ins for every input of the step function.

    train  -> batch dict for loss/train_step
    prefill-> batch dict (+ max_len convention: cache sized to shape.seq)
    decode -> (cache pytree, tokens)
    """
    B, S = shape.batch, shape.seq
    i32 = jnp.int32
    if cfg.family == "encdec":
        d = cfg.d_model
        if shape.kind == "train":
            return {"frames": jax.ShapeDtypeStruct((B, S, d), cfg.cdt),
                    "dec_tokens": jax.ShapeDtypeStruct((B, max(2, S // 8)), i32)}
        if shape.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct((B, S, d), cfg.cdt),
                    "dec_tokens": jax.ShapeDtypeStruct((B, 8), i32)}
        model = build(cfg)
        cache = jax.eval_shape(
            lambda: model.init_cache(B, S, enc_len=WHISPER_ENC_LEN))
        tokens = jax.ShapeDtypeStruct((B, 1), i32)
        return cache, tokens

    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.pos == "mrope":
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.pos == "mrope":
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return batch
    model = build(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    tokens = jax.ShapeDtypeStruct((B, 1), i32)
    return cache, tokens


def concrete_inputs(cfg: ModelConfig, shape: Shape, key=None):
    """Small concrete batch for smoke tests (reduced shapes only)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    spec = input_specs(cfg, shape)

    def mk(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.zeros(s.shape, s.dtype) + (jnp.arange(s.shape[-1], dtype=s.dtype) %
                                                  max(2, cfg.vocab - 1) if s.shape else 0)
        return jnp.ones(s.shape, s.dtype) * 0.01

    return jax.tree.map(mk, spec)
