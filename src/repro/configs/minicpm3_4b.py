"""minicpm3-4b [dense, MLA] — 62L d_model=2560 40H d_ff=6400 vocab=73448
[hf:openbmb/MiniCPM3-4B].  MLA dims per the HF config: q_lora 768,
kv_lora 256, qk_nope 64, qk_rope 32, v_head 64."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="minicpm3-4b", family="dense", attn_kind="mla",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448, head_dim=96,          # qk_nope + qk_rope
    q_lora_rank=768, kv_lora_rank=256, qk_rope_dim=32, qk_nope_dim=64,
    v_head_dim=64, tie_embeddings=True, pad_heads_to=16,
)

SMOKE = ModelConfig(
    arch="minicpm3-4b-smoke", family="dense", attn_kind="mla",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=24,
    q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
    v_head_dim=16, tie_embeddings=True, attn_block=32,
)
