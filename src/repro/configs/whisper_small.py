"""whisper-small [audio, enc-dec] — 12L (enc) + 12L (dec) d_model=768 12H
d_ff=3072 vocab=51865 [arXiv:2212.04356].  The mel/conv frontend is a STUB:
input_specs provides precomputed frame embeddings (per the assignment)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-small", family="encdec",
    n_layers=24, enc_layers=12, dec_layers=12,
    d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64, pad_heads_to=16,
    pos="learned", max_positions=32768,       # decoder table covers decode_32k
)

SMOKE = ModelConfig(
    arch="whisper-small-smoke", family="encdec",
    n_layers=4, enc_layers=2, dec_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    pos="learned", max_positions=128, attn_block=32,
)
