"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

TPU-native adaptation of the SSD algorithm: one grid cell = (batch·head,
chunk).  The chunk dimension is the innermost, "arbitrary" grid axis; the
running inter-chunk state (P x N, fp32) lives in VMEM scratch and carries
across chunks — the sequential recurrence never touches HBM.  The
intra-chunk block (Q x Q decay-masked attention-like matmul) is MXU work;
Q=chunk, P=head_dim, N=state are all 128-aligned for the production config
(mamba2-780m: Q=256, P=64, N=128).

Oracle: ``repro.kernels.ref.ssd_ref`` (also the CPU execution path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, st_out_ref, state_scr,
            *, nchunks, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)          # (Q,)
    A = A_ref[0].astype(jnp.float32)            # (1,) scalar for this head
    Bm = B_ref[0].astype(jnp.float32)           # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)           # (Q, N)

    dA = dt * A[0]                              # (Q,)
    cums = jnp.cumsum(dA)                       # (Q,)
    xd = x * dt[:, None]

    # intra-chunk: L[i,j] = exp(cums[i]-cums[j]) for i>=j else 0
    # (mask before exp: above-diagonal seg is large-positive)
    seg = cums[:, None] - cums[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.exp(jnp.where(tri, seg, -jnp.inf))
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ()))) * L  # (Q,Q)
    y = jax.lax.dot(scores, xd)                                        # (Q,P)

    # inter-chunk contribution from the carried state
    state = state_scr[...]                                             # (P,N)
    y += jnp.exp(cums)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())))                           # (Q,P)

    # state update: state' = e^{sum dA} * state + sum_i e^{sum-cums_i} xd_i B_i^T
    total = cums[chunk - 1]
    decay = jnp.exp(total - cums)                                      # (Q,)
    upd = jax.lax.dot_general(xd * decay[:, None], Bm,
                              (((0,), (0,)), ((), ())))                # (P,N)
    state_scr[...] = jnp.exp(total) * state + upd

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nchunks - 1)
    def _emit_state():
        st_out_ref[0] = state_scr[...]


def ssd_pallas(x, dt, A, B, C, *, chunk=256, interpret=False):
    """Same contract as ``ref.ssd_ref``: x (b,s,h,p), dt (b,s,h), A (h,),
    B/C (b,s,g,n).  Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert s % chunk == 0
    nc = s // chunk

    # layout: one row per (batch, head)
    xr = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(b * h, s)
    Ar = jnp.tile(A, b).reshape(b * h, 1)
    Br = B.transpose(0, 2, 1, 3).reshape(b * g, s, n)
    Cr = C.transpose(0, 2, 1, 3).reshape(b * g, s, n)

    kern = functools.partial(_kernel, nchunks=nc, chunk=chunk)
    y, st = pl.pallas_call(
        kern,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, chunk), lambda r, c: (r, c)),
            pl.BlockSpec((1, 1), lambda r, c: (r, 0)),
            pl.BlockSpec((1, chunk, n), lambda r, c, rep=rep: (r // rep, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda r, c, rep=rep: (r // rep, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, p, n), lambda r, c: (r, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((p, n))],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(xr, dtr, Ar, Br, Cr)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    st = st.reshape(b, h, p, n)
    return y, st


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _tpu_params():
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    except Exception:
        return None
