"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: kernels are validated against them in
interpret mode across shape/dtype sweeps, and the models use them as the
portable (CPU / dry-run) execution path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ attention ref

def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: Optional[float] = None):
    """Materialized-scores attention. q: (B,Sq,H,D); k/v: (B,Skv,KV,Dk/Dv)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k.astype(jnp.float32))
    s *= scale if scale is not None else 1.0 / (D ** 0.5)
    iq = jnp.arange(Sq)[:, None]
    jk = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= jk <= iq
    if window:
        mask &= jk > iq - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, -1).astype(q.dtype)


# ------------------------------------------------------------------ SSD ref

def segsum(x):
    """x: (..., T) -> (..., T, T); out[..., i, j] = sum_{j < k <= i} x[..., k],
    -inf above the diagonal (the 1-SS decay matrix in log space)."""
    T = x.shape[-1]
    xe = jnp.broadcast_to(x[..., None, :], (*x.shape, T))  # [..., i, j] = x[j]... wait
    xe = jnp.swapaxes(xe, -1, -2)                          # [..., i, j] = x[i]
    mask = jnp.tril(jnp.ones((T, T), bool), -1)
    xe = jnp.where(mask, xe, 0.0)
    out = jnp.cumsum(xe, axis=-2)
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_ref(x, dt, A, B, C, *, chunk: int = 256, initial_state=None):
    """Mamba-2 state-space duality (SSD), chunked exact algorithm.

    x: (b, s, h, p)   dt: (b, s, h)  post-softplus
    A: (h,)           negative real
    B, C: (b, s, g, n) with h % g == 0
    Returns (y: (b, s, h, p), final_state: (b, h, p, n)).

    Implemented as a ``lax.scan`` over chunks carrying the (b,h,p,n) state:
    only ONE chunk's (l x l) decay block is ever materialized.  (The naive
    all-chunks-at-once formulation materializes (b,h,nc,l,l) tensors and was
    the dominant memory-roofline term for the SSM/hybrid archs — see
    EXPERIMENTS.md §Perf hillclimb 2.  The Pallas kernel is the same
    algorithm with the state in VMEM scratch.)
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, "pad sequence to a chunk multiple upstream"
    nc = s // chunk
    rep = h // g

    xd = (x * dt[..., None]).reshape(b, nc, chunk, h, p).astype(jnp.float32)
    Be = jnp.repeat(B, rep, axis=2).reshape(b, nc, chunk, h, n).astype(jnp.float32)
    Ce = jnp.repeat(C, rep, axis=2).reshape(b, nc, chunk, h, n).astype(jnp.float32)
    dA = (dt * A).reshape(b, nc, chunk, h).astype(jnp.float32)   # (b,c,l,h)

    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(state, inp):
        xd_c, Be_c, Ce_c, dA_c = inp          # (b,l,h,p) (b,l,h,n) .. (b,l,h)
        cums = jnp.cumsum(dA_c, axis=1)       # (b,l,h)
        seg = cums[:, :, None, :] - cums[:, None, :, :]        # (b,l,s,h)
        # mask BEFORE exp: above-diagonal seg is large-positive (cums is
        # decreasing), and grad(where(m, exp(inf), 0)) = 0*inf = NaN
        seg = jnp.where(tri[None, :, :, None], seg, -jnp.inf)
        L = jnp.exp(seg)
        scores = jnp.einsum("blhn,bshn->blsh", Ce_c, Be_c) * L
        y = jnp.einsum("blsh,bshp->blhp", scores, xd_c)        # intra-chunk
        y += jnp.einsum("blhn,bhpn,blh->blhp", Ce_c, state, jnp.exp(cums))
        decay = jnp.exp(cums[:, -1:, :] - cums)                # (b,l,h)
        upd = jnp.einsum("blhp,blh,blhn->bhpn", xd_c, decay, Be_c)
        state = state * jnp.exp(cums[:, -1, :])[:, :, None, None] + upd
        return state, y

    final, ys = jax.lax.scan(
        step, initial_state.astype(jnp.float32),
        (xd.transpose(1, 0, 2, 3, 4), Be.transpose(1, 0, 2, 3, 4),
         Ce.transpose(1, 0, 2, 3, 4), dA.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p).astype(x.dtype)
    return y, final


def ssd_decode_ref(x, dt, A, B, C, state):
    """One-token SSD recurrence.  x: (b,h,p); dt: (b,h); B,C: (b,g,n);
    state: (b,h,p,n).  Returns (y: (b,h,p), state)."""
    b, h, p = x.shape
    g, n = B.shape[1], B.shape[2]
    rep = h // g
    Be = jnp.repeat(B, rep, axis=1).astype(jnp.float32)    # (b,h,n)
    Ce = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))  # (b,h)
    xd = (x * dt[..., None]).astype(jnp.float32)
    state = state * dA[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xd, Be)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ce)
    return y.astype(x.dtype), state


# ------------------------------------------------------------- quantize ref

def quantize_ref(x, *, group: int = 256):
    """Symmetric int8 group quantization along the last axis.

    Returns (q: int8 same shape, scales: float32 (..., n_groups))."""
    shape = x.shape
    assert shape[-1] % group == 0
    xg = x.reshape(*shape[:-1], shape[-1] // group, group).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xg), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xg / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale


def dequantize_ref(q, scale, *, group: int = 256, dtype=jnp.float32):
    shape = q.shape
    qg = q.reshape(*shape[:-1], shape[-1] // group, group).astype(jnp.float32)
    return (qg * scale[..., None]).reshape(shape).astype(dtype)
