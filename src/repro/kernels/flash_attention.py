"""Flash attention (forward) as a Pallas TPU kernel.

TPU-native adaptation: the working set per grid cell is one q-tile
(blk_q x D) held in VMEM with running max / denominator / accumulator in
VMEM scratch; the kv-sequence is the innermost ("arbitrary") grid dim so
the accumulator carries across kv tiles without HBM round-trips.  Tiles
are MXU-aligned (128 lanes).  Causal / sliding-window tiles that are fully
masked are skipped with ``pl.when`` — on TPU that prunes ~half the MXU work
for causal prefill.

The jnp oracle is ``repro.kernels.ref.attention_ref``; CPU tests run this
kernel with ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, blk_q, blk_k, n_kv, seq_q, seq_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k
    relevant = True
    if causal:
        relevant = k_start <= q_start + blk_q - 1
    if window is not None:
        relevant = jnp.logical_and(relevant,
                                   k_start + blk_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (blk_q, D)
        k = k_ref[0].astype(jnp.float32)          # (blk_k, D)
        v = v_ref[0].astype(jnp.float32)          # (blk_k, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        iq = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        jk = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jk < seq_kv
        if causal:
            mask = jnp.logical_and(mask, jk <= iq)
        if window is not None:
            mask = jnp.logical_and(mask, jk > iq - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _out():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           blk_q=128, blk_k=128, interpret=False):
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D/Dv).  Returns (B, Sq, H, Dv)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, Dv = v.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)
    if window == 0:
        window = None

    pad_q = (-Sq) % blk_q
    pad_k = (-Skv) % blk_k
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, Dv)
    if pad_q:
        qr = jnp.pad(qr, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kr = jnp.pad(kr, ((0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pad_k), (0, 0)))
    n_q = qr.shape[1] // blk_q
    n_kv = kr.shape[1] // blk_k

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_kv=n_kv, seq_q=Sq, seq_kv=Skv)

    out = pl.pallas_call(
        kern,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, blk_k, Dv), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, n_q * blk_q, Dv), q.dtype),
        scratch_shapes=[
            _vmem((blk_q, 1)),
            _vmem((blk_q, 1)),
            _vmem((blk_q, Dv)),
        ],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(qr, kr, vr)
    out = out[:, :Sq].reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)
    return out


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _tpu_params():
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:
        return None
