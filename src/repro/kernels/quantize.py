"""Symmetric int8 group quantization as a Pallas TPU kernel.

Used on the checkpoint path (shards are quantized before being appended to
the NVMM log — smaller entries defer the paper's Fig.-5 log-saturation
point) and for compressed gradient all-reduce.  One grid cell quantizes a
(blk_r x group) VMEM tile: an amax reduction plus an elementwise scale —
bandwidth-bound by design, tiles sized to stream through VMEM.

Oracle: ``repro.kernels.ref.quantize_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)              # (blk_r, group)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_pallas(x, *, group=256, blk_r=256, interpret=False):
    """x: any shape with last dim divisible by ``group``.
    Returns (q int8 same shape, scales f32 (..., last/group))."""
    shape = x.shape
    assert shape[-1] % group == 0
    ng = shape[-1] // group
    rows = 1
    for d in shape[:-1]:
        rows *= d
    xr = x.reshape(rows * ng, group)
    R = xr.shape[0]
    blk = min(blk_r, R)
    pad = (-R) % blk
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))

    q, s = pl.pallas_call(
        _kernel,
        grid=(xr.shape[0] // blk,),
        in_specs=[pl.BlockSpec((blk, group), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((blk, group), lambda i: (i, 0)),
                   pl.BlockSpec((blk, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(xr.shape, jnp.int8),
                   jax.ShapeDtypeStruct((xr.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(xr)
    q = q[:R].reshape(shape)
    s = s[:R, 0].reshape(*shape[:-1], ng)
    return q, s
