"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas implementations run; everywhere else (this CPU container,
including the 512-fake-device dry-run) the jnp oracles from ``ref.py`` run —
same semantics, validated against each other in ``tests/test_kernels_*``.
Set ``REPRO_FORCE_PALLAS_INTERPRET=1`` to exercise the kernel bodies in
interpret mode outside tests.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


def _interpret() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS_INTERPRET", "0") == "1"


# ---------------------------------------------------------- flash attention

def flash_attention(q, k, v, *, causal=True, window=0, scale=None):
    if _on_tpu() or _interpret():
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale, interpret=not _on_tpu())
    return _ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)


# --------------------------------------------------------------------- SSD

def ssd(x, dt, A, B, C, *, chunk=256):
    if _on_tpu() or _interpret():
        from repro.kernels.ssd_scan import ssd_pallas
        return ssd_pallas(x, dt, A, B, C, chunk=chunk, interpret=not _on_tpu())
    return _ref.ssd_ref(x, dt, A, B, C, chunk=chunk)


# ----------------------------------------------------------------- quantize

def quantize(x, *, group=256):
    if _on_tpu() or _interpret():
        from repro.kernels.quantize import quantize_pallas
        return quantize_pallas(x, group=group, interpret=not _on_tpu())
    return _ref.quantize_ref(x, group=group)


def dequantize(q, scale, *, group=256, dtype=jnp.float32):
    return _ref.dequantize_ref(q, scale, group=group, dtype=dtype)
