"""§Perf probe: dry-run one cell with config overrides (hypothesis testing
without touching the committed configs).

  PYTHONPATH=src python scripts/perf_probe.py llama3.2-1b train_4k remat=none
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import sys

sys.path.insert(0, "benchmarks")

import jax  # noqa: E402

import hlo_analysis  # noqa: E402
from repro.configs import registry  # noqa: E402
from repro.configs.shapes import SHAPES, input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.registry import build  # noqa: E402
from repro.optim.adamw import AdamW  # noqa: E402
from repro.train import steps as tsteps  # noqa: E402


def probe(arch, shape_name, **overrides):
    cfg = dataclasses.replace(registry.get_config(arch), **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    model = build(cfg)
    with mesh:
        if shape.kind == "train":
            opt = AdamW(moment_dtype="bfloat16" if cfg.param_dtype == "bfloat16" else None)
            step = tsteps.bind_mesh(tsteps.make_train_step(model, opt), mesh)
            spec = input_specs(cfg, shape)
            (in_sh, b_sh), (out_sh, _), state_abs = tsteps.train_shardings(
                model, opt, mesh, spec)
            lowered = jax.jit(step, in_shardings=(in_sh, b_sh),
                              out_shardings=(out_sh, None),
                              donate_argnums=(0,)).lower(state_abs, spec)
        elif shape.kind == "prefill":
            step = tsteps.bind_mesh(tsteps.make_prefill_step(model, shape.seq), mesh)
            spec = input_specs(cfg, shape)
            shards, params_abs = tsteps.serve_shardings(
                model, mesh, jax.eval_shape(
                    lambda: model.init_cache(shape.batch, shape.seq)),
                batch_like=spec)
            lowered = jax.jit(step, in_shardings=(shards["params"], shards["batch"]),
                              out_shardings=(None, shards["cache"])).lower(params_abs, spec)
        else:
            raise SystemExit("probe supports train/prefill")
        compiled = lowered.compile()
    r = hlo_analysis.analyze(compiled.as_text())
    print(f"{arch} {shape_name} {overrides}: "
          f"t=({r['flops'] / 197e12:.3f},{r['hbm_bytes'] / 819e9:.3f},"
          f"{r['wire_bytes'] / 50e9:.3f})s flops={r['flops']:.3e}")
    return r


if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    ov = {}
    for kv in sys.argv[3:]:
        k, v = kv.split("=")
        ov[k] = v if not v.replace(".", "").lstrip("-").isdigit() else (
            int(v) if "." not in v else float(v))
    probe(arch, shape, **ov)
